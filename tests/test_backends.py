"""Plan/execute split + pluggable execution backends (ISSUE 3).

* PLANNER PARITY — the same trace through the analytic and the exec
  engine yields IDENTICAL per-step primitive decisions and dispatch
  plans (the planner is backend-independent by construction; these tests
  keep it that way).
* EXEC EXACTNESS — the JaxExecBackend's decode outputs reproduce
  single-instance attention over each request's concatenated chunks to
  float round-off, regardless of which primitive the predicate picked
  (§3.3, end-to-end through the scheduler) — asserted on all three
  golden traces (routed-only / fetch-heavy / mixed-congested).
* fabric calibration (benchmarks/calibrate_fabric.py) round-trips
  through Fabric.from_json / load_table / register_fabrics, and the
  serve CLI drives both backends from one saved trace.
"""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from engine_scenarios import SCENARIOS
from repro.core import constants as C
from repro.core.constants import Fabric, register_fabrics
from repro.models.mla import absorbed_partial
from repro.serving.backends import (AnalyticBackend, ExecutionBackend,
                                    JaxExecBackend, TINY_MLA)
from repro.serving.backends.jax_exec import (chunk_array, oracle_partial,
                                             query_for)
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.workload import (WorkloadConfig, agentic_trace,
                                    load_trace, materialize_trace,
                                    register_corpus, save_trace)

RTOL, ATOL = 2e-5, 1e-6


def _run(build, backend=None):
    """Drive one scenario; returns (engine, per-step request lists)."""
    eng, steps = build(backend)
    for reqs in steps:
        eng.schedule_step(reqs)
    return eng, steps


def _record_key(r):
    return (r.step, r.primitive, r.chunk_id, r.holder, r.n_requesters,
            r.m_q_total, r.backup, r.fabric_idx, r.link_instance, r.home,
            r.req_ids, r.est_cost_s, r.stages)


# ---------------------------------------------------------------------------
# Planner parity: analytic vs exec.
# ---------------------------------------------------------------------------

class TestBackendParity:
    def test_default_backend_is_analytic(self):
        eng = ServingEngine(2, pool_tokens=10**4)
        assert eng.backend.name == "analytic"
        assert isinstance(eng.backend, AnalyticBackend)
        assert isinstance(eng.backend, ExecutionBackend)
        assert isinstance(JaxExecBackend(), ExecutionBackend)

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_identical_decisions_and_plans(self, name):
        """Same trace -> identical per-step primitive decisions AND full
        dispatch plans (costs, stages, grouping) across backends."""
        ana, _ = _run(SCENARIOS[name], AnalyticBackend())
        exe, _ = _run(SCENARIOS[name], JaxExecBackend())
        assert [_record_key(r) for r in ana.log] \
            == [_record_key(r) for r in exe.log]
        for sa, se in zip(ana.stats, exe.stats):
            assert sa.primitives == se.primitives
            assert sa.n_resident == se.n_resident
            assert sa.latency_s == se.latency_s            # same timeline
            assert sa.stage_totals == se.stage_totals
        # analytic produced no outputs; exec produced them for every step
        assert all(not o for o in ana.step_outputs)
        assert all(exe.step_outputs)

    def test_parity_on_agentic_workload(self):
        """The generated (sessioned, Zipf) workload drives both backends to
        the same decisions too — not just the hand-built scenarios."""
        def build(backend):
            eng = ServingEngine(4, pool_tokens=32 * 256,
                                cfg=EngineConfig(), instances_per_pod=2,
                                backend=backend)
            wl = WorkloadConfig(n_steps=10, agents=8, n_corpus_chunks=6,
                                chunk_tokens=256, session_steps=(2, 6),
                                seed=3)
            cids = register_corpus(eng, wl)
            return eng, materialize_trace(agentic_trace(wl, eng, cids))
        ana, steps_a = build(AnalyticBackend())
        exe, steps_e = build(JaxExecBackend())
        assert [[dataclasses.asdict(r) for r in s] for s in steps_a] \
            == [[dataclasses.asdict(r) for r in s] for s in steps_e]
        # the workload's selection_frac puts some sessions in the §5.4
        # regime with NO selector configured: the engines' warn-once
        # fallback RuntimeWarning is intentional here — assert it instead
        # of leaking it (tier-1 runs with filterwarnings = error)
        with pytest.warns(RuntimeWarning, match="k_selected"):
            for reqs_a, reqs_e in zip(steps_a, steps_e):
                ana.schedule_step(reqs_a)
                exe.schedule_step(reqs_e)
        assert [_record_key(r) for r in ana.log] \
            == [_record_key(r) for r in exe.log]


# ---------------------------------------------------------------------------
# Exec exactness: scheduler-driven attention == single-instance attention.
# ---------------------------------------------------------------------------

def _assert_step_exact(eng: ServingEngine, reqs, step: int):
    outs = eng.outputs_of(step)
    for rq in reqs:
        assert rq.req_id in outs, (step, rq.req_id)
        got = outs[rq.req_id]
        want = oracle_partial(TINY_MLA, eng.store, rq, step)
        np.testing.assert_allclose(got.o, want.o, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(got.m, want.m, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(got.l, want.l, rtol=RTOL, atol=ATOL)
        assert got.o.shape == (rq.m_q, TINY_MLA.n_heads,
                               TINY_MLA.kv_lora_rank)


class TestExecExactness:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_matches_single_instance_attention(self, name):
        """Routed, fetched (spliced replica), local and resident accesses
        all reproduce attention over the request's concatenated chunks."""
        eng, steps = SCENARIOS[name](JaxExecBackend())
        for reqs in steps:
            eng.schedule_step(reqs)
            _assert_step_exact(eng, reqs, eng.step_idx)

    def test_fetch_persists_real_replica_bytes(self):
        """A persisted FETCH leaves the spliced array on the requester; the
        next step's resident access attends THAT copy and stays exact."""
        eng = ServingEngine(4, pool_tokens=10**5,
                            backend=JaxExecBackend())
        eng.register_chunk("doc", holder=1, length=64)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=2,
                     expected_reuse_steps=100_000)
        assert [r.primitive for r in eng.schedule_step([rq])] == ["fetch"]
        rep = eng.store.array_on("doc", 0)
        assert rep is not None and rep.shape == (64, TINY_MLA.d_qk)
        # delta-0 splice: the replica equals the canonical bytes exactly
        np.testing.assert_allclose(rep, eng.store.lookup("doc").data,
                                   rtol=0, atol=0)
        assert eng.schedule_step([rq]) == []       # resident now
        _assert_step_exact(eng, [rq], eng.step_idx)

    def test_exactness_survives_holder_failure(self):
        """Orphaned chunk -> LOCAL re-prefill path regenerates the same
        canonical entries, so outputs stay exact after a failure."""
        eng = ServingEngine(4, pool_tokens=10**5,
                            backend=JaxExecBackend())
        eng.register_chunk("doc", holder=1, length=32)
        rq = Request(0, home=0, chunk_ids=["doc"], m_q=4)
        eng.schedule_step([rq])
        assert eng.fail_instance(1) == ["doc"]
        recs = eng.schedule_step([rq])
        assert [r.primitive for r in recs] == ["local"]
        _assert_step_exact(eng, [rq], eng.step_idx)

    def test_output_retention_window(self):
        """Old steps' output arrays are released (bounded memory over a
        long exec run); recent steps stay queryable."""
        eng = ServingEngine(4, pool_tokens=10**5,
                            cfg=EngineConfig(retain_outputs=2),
                            backend=JaxExecBackend())
        eng.register_chunk("c", holder=1, length=16)
        rq = Request(0, home=0, chunk_ids=["c"], m_q=1)
        for _ in range(4):
            eng.schedule_step([rq])
        assert eng.outputs_of(1) == {} and eng.outputs_of(2) == {}
        assert eng.outputs_of(3) and eng.outputs_of(4)

    def test_deterministic_materialization(self):
        """Chunk arrays and query tensors are pure functions of ids/seeds:
        two independent engines materialize identical bytes."""
        a = chunk_array(TINY_MLA, "corpus_0001", 16)
        b = chunk_array(TINY_MLA, "corpus_0001", 16)
        np.testing.assert_array_equal(a, b)
        r1 = Request(7, home=0, chunk_ids=["x"], m_q=3, query_seed=42)
        np.testing.assert_array_equal(query_for(TINY_MLA, r1, 5),
                                      query_for(TINY_MLA, r1, 5))
        assert not np.array_equal(query_for(TINY_MLA, r1, 5),
                                  query_for(TINY_MLA, r1, 6))


# ---------------------------------------------------------------------------
# Fetch source resolution + exec-mode failover (ISSUE 7 satellites).
# ---------------------------------------------------------------------------


class TestFetchSourceResolution:
    def test_shared_resolver(self):
        """Both fetch exec paths resolve the wire source through ONE
        function: link_instance when the planner set it (fetch_replica
        spawns carry the canonical holder there — their `holder` field is
        the TARGET), else the record's holder."""
        from repro.serving.backends.jax_exec import fetch_source
        rec = dataclasses.make_dataclass(
            "R", ["link_instance", "holder"])(link_instance=2, holder=5)
        assert fetch_source(rec) == 2              # fetch_replica shape
        rec = dataclasses.make_dataclass(
            "R", ["link_instance", "holder"])(link_instance=-1, holder=5)
        assert fetch_source(rec) == 5              # no-wire fallback

    def test_selected_fetch_rejects_replica_spawn(self):
        """fetch_replica-under-selection is unreachable by construction
        (replica spawns batch only dense overflow); the exec path pins it
        with an assertion so the source resolution cannot silently
        diverge again."""
        backend = JaxExecBackend()
        rec = dataclasses.make_dataclass(
            "R", ["primitive", "req_ids", "link_instance", "holder"])(
            primitive="fetch_replica", req_ids=(0,), link_instance=1,
            holder=2)
        with pytest.raises(AssertionError, match="replica spawns"):
            backend._exec_fetch_selected(None, rec, None, None, None)

    def test_exec_serves_from_promoted_replica(self):
        """Exec-mode failover: a persisted replica survives its canonical
        holder's death (promotion), and the NEXT step's execution attends
        the promoted copy — outputs stay exact (ISSUE 7 satellite)."""
        eng, steps = SCENARIOS["fetch_heavy"](JaxExecBackend())
        eng.schedule_step(steps[0])        # FETCHes persist replicas on 0
        assert eng.store.array_on("doc0", 0) is not None
        assert eng.fail_instance(1) == []  # doc0 promoted, not orphaned
        assert eng.store.lookup("doc0").holder == 0
        rq = Request(7, home=3, chunk_ids=["doc0"], m_q=4)
        eng.schedule_step([rq])
        _assert_step_exact(eng, [rq], eng.step_idx)

    def test_analytic_and_exec_record_no_measured_report(self):
        """measured_reports stays aligned with stats for every backend;
        only the shard_map backend fills it (tested in the mesh prog)."""
        for backend in (AnalyticBackend(), JaxExecBackend()):
            eng, steps = SCENARIOS["routed_only"](backend)
            for reqs in steps:
                eng.schedule_step(reqs)
            assert len(eng.measured_reports) == len(eng.stats)
            assert all(r is None for r in eng.measured_reports)


# ---------------------------------------------------------------------------
# Up-front shard-shape validation (ISSUE 7 satellite; in-process — the
# checks are host-side shape logic, no mesh needed).
# ---------------------------------------------------------------------------


class TestShardShapeValidation:
    def test_route_shards_name_axis_shard_and_shapes(self):
        from repro.core.routing import check_route_shards
        with pytest.raises(ValueError, match=r"shard 3.*d_qk=24.*d_qk=16"):
            check_route_shards("instance", np.zeros((4, 2, 24)),
                               np.zeros((64, 16)), shard=3)
        with pytest.raises(ValueError, match=r"S_local=63.*S_local=64"):
            check_route_shards("instance", np.zeros((4, 2, 24)),
                               np.zeros((64, 24)), np.zeros(63, bool))
        # well-formed shards pass silently
        check_route_shards("instance", np.zeros((4, 2, 24)),
                           np.zeros((64, 24)), np.zeros(64, bool), shard=1)

    def test_instance_shards_name_shard_and_both_shapes(self):
        from repro.serving.backends.shard_map import check_instance_shards
        with pytest.raises(ValueError,
                           match=r"shard 2.*\(7, 4\).*\(8, 4\)"):
            check_instance_shards({0: np.zeros((8, 4)),
                                   2: np.zeros((7, 4))}, (8, 4), 8)
        with pytest.raises(ValueError, match="outside the mesh"):
            check_instance_shards({9: np.zeros((8, 4))}, (8, 4), 8)
        check_instance_shards({0: np.zeros((8, 4))}, (8, 4), 8)


# ---------------------------------------------------------------------------
# Array-bearing chunk store.
# ---------------------------------------------------------------------------

class TestChunkStoreArrays:
    def test_attach_validates_length(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        st.register("c", holder=0, length=8)
        with pytest.raises(ValueError):
            st.attach_data("c", jnp.zeros((9, 4)))
        st.attach_data("c", jnp.zeros((8, 4)))
        assert st.array_on("c", 0).shape == (8, 4)
        assert st.array_on("c", 1) is None            # not resident

    def test_register_with_data_validates_too(self):
        """register(data=...) enforces the same length check as
        attach_data — and a failed registration leaves no trace."""
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        with pytest.raises(ValueError):
            st.register("c", holder=0, length=8, data=jnp.zeros((9, 4)))
        assert st.used(0) == 0                        # allocation rolled back
        st.register("c", holder=0, length=8, data=jnp.zeros((8, 4)))
        assert st.array_on("c", 0).shape == (8, 4)

    def test_eviction_drops_replica_bytes(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        st.register("c", holder=0, length=8, data=jnp.ones((8, 4)))
        st.add_replica("c", 1)
        st.set_replica_data("c", 1, jnp.ones((8, 4)) * 2)
        assert float(st.array_on("c", 1)[0, 0]) == 2.0
        st.evict_replica("c", 1)
        assert st.array_on("c", 1) is None

    def test_holder_failure_promotes_replica_bytes(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(2, 10**4)
        st.register("c", holder=0, length=8, data=jnp.ones((8, 4)))
        st.add_replica("c", 1)
        st.set_replica_data("c", 1, jnp.ones((8, 4)) * 3)
        assert st.drop_holder(0) == []
        c = st.lookup("c")
        assert c.holder == 1 and float(c.data[0, 0]) == 3.0


# ---------------------------------------------------------------------------
# Fabric calibration + JSON tables (satellite).
# ---------------------------------------------------------------------------

class TestFabricTables:
    def test_json_roundtrip(self):
        fab = C.fabric("h100_ibgda")
        back = Fabric.from_json(json.loads(json.dumps(fab.to_json())))
        assert back == fab
        # unknown keys (fit diagnostics) are ignored
        assert Fabric.from_json(dict(fab.to_json(), mape_pct=3.1)) == fab
        with pytest.raises(ValueError):
            Fabric.from_json({"t_probe_s": 1e-6, "bw_Bps": 1e9,
                              "link_peak_Bps": 1e9})

    def test_calibrate_writes_loadable_table(self, tmp_path):
        from benchmarks import calibrate_fabric as cf
        out = tmp_path / "table.json"
        cf.main(["--fabrics", "tpu_ici", "h100_ibgda",
                 "--out", str(out)])
        table = Fabric.load_table(out)
        assert set(table) == {"tpu_ici_fit", "h100_ibgda_fit"}
        # noiseless model sweep recovers the two constants (BW exactly up
        # to fit arithmetic; probe absorbs the t_launch residual)
        ici = table["tpu_ici_fit"]
        assert ici.bw_Bps == pytest.approx(C.fabric("tpu_ici").bw_Bps,
                                           rel=1e-6)
        assert ici.t_probe_s == pytest.approx(
            C.fabric("tpu_ici").t_probe_s, rel=1e-3)
        register_fabrics(table)
        try:
            assert C.fabric("tpu_ici_fit") == ici
            # an engine runs on the measured table
            eng = ServingEngine(
                4, pool_tokens=10**5,
                cfg=EngineConfig(intra_pod_fabric="tpu_ici_fit",
                                 cross_pod_fabric="h100_ibgda_fit"),
                instances_per_pod=2)
            eng.register_chunk("c", holder=1, length=2048)
            recs = eng.schedule_step(
                [Request(0, home=0, chunk_ids=["c"], m_q=64)])
            assert [r.primitive for r in recs] == ["route"]
        finally:
            for name in table:
                C.FABRICS.pop(name, None)

    def test_register_no_overwrite(self):
        ref = C.fabric("tpu_ici")
        other = Fabric("tpu_ici", 9e-6, 1e9, 1e9)
        register_fabrics({"tpu_ici": other}, overwrite=False)
        assert C.fabric("tpu_ici") == ref
        register_fabrics({"tpu_ici": other})
        try:
            assert C.fabric("tpu_ici") == other
        finally:
            register_fabrics({"tpu_ici": ref})

    def test_calibrate_run_rows(self):
        from benchmarks import calibrate_fabric as cf
        rows = cf.run()
        assert len(rows) == len(cf.DEFAULT_FABRICS)
        assert all(r["bw_err_pct"] < 2.0 for r in rows)


# ---------------------------------------------------------------------------
# Serve CLI: one saved trace drives both backends (satellite).
# ---------------------------------------------------------------------------

class TestServeCLI:
    ARGS = ["--instances", "4", "--pods", "2", "--chunks", "6",
            "--chunk-tokens", "64", "--agents", "6", "--steps", "3"]

    def test_workload_not_inline_rng(self, tmp_path, capsys):
        """The CLI builds its trace via serving.workload: requests carry
        session reuse horizons (amortisation can accrue), not the old
        inline loop's constant reuse=1."""
        from repro.launch import serve
        trace = tmp_path / "t.json"
        serve.main(self.ARGS + ["--save-trace", str(trace)])
        assert "backend=analytic" in capsys.readouterr().out
        steps = load_trace(trace)
        assert len(steps) == 3 and len(steps[0]) == 6
        assert any(rq.expected_reuse_steps > 1
                   for step in steps for rq in step)
        assert all(rq.query_seed is not None
                   for step in steps for rq in step)

    def test_same_trace_both_backends(self, tmp_path, capsys):
        from repro.launch import serve
        trace = tmp_path / "t.json"
        serve.main(self.ARGS + ["--save-trace", str(trace)])
        capsys.readouterr()
        serve.main(self.ARGS + ["--trace", str(trace),
                                "--backend", "exec", "--verify"])
        out = capsys.readouterr().out
        assert "backend=exec" in out
        for line in out.splitlines():
            if "max|err|" in line:
                assert float(line.rsplit("max|err| ", 1)[1]) < 1e-4

    def test_replay_reconstructs_recorded_world(self, tmp_path, capsys):
        """A replay with mismatched flags must rebuild the corpus the
        trace was recorded against (meta header), not trust the flags —
        otherwise chunk geometry silently changes every decision."""
        from repro.launch import serve
        from repro.serving.workload import trace_meta
        trace = tmp_path / "t.json"
        serve.main(self.ARGS + ["--save-trace", str(trace)])
        assert trace_meta(trace)["chunk_tokens"] == 64
        capsys.readouterr()
        # replay with DIFFERENT corpus flags: meta must win
        serve.main(["--instances", "8", "--chunks", "16",
                    "--chunk-tokens", "2048", "--steps", "3",
                    "--trace", str(trace), "--backend", "exec", "--verify"])
        out = capsys.readouterr().out
        assert "meta overrides --chunk-tokens: 2048 -> 64" in out
        for line in out.splitlines():
            if "max|err|" in line:
                assert float(line.rsplit("max|err| ", 1)[1]) < 1e-4

    def test_verify_requires_exec_backend(self):
        from repro.launch import serve
        with pytest.raises(SystemExit, match="--backend exec"):
            serve.main(self.ARGS + ["--verify"])

    def test_save_and_replay_flags_conflict(self, tmp_path):
        from repro.launch import serve
        with pytest.raises(SystemExit, match="cannot"):
            serve.main(self.ARGS + ["--trace", str(tmp_path / "a.json"),
                                    "--save-trace",
                                    str(tmp_path / "b.json")])


# ---------------------------------------------------------------------------
# The planner must stay importable (and runnable) without jax.
# ---------------------------------------------------------------------------

def test_planner_importable_without_jax():
    """repro.serving's planner + analytic backend are numpy-only; the
    jax-dependent exec backend loads lazily. Simulate a jax-free
    environment in a subprocess with an import blocker."""
    import os
    import pathlib
    import subprocess
    import sys
    import repro
    # repro is a namespace package: __file__ is None, use __path__
    src = str(pathlib.Path(list(repro.__path__)[0]).resolve().parent)
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    prog = (
        "import sys\n"
        "class Block:\n"
        "    def find_module(self, name, path=None):\n"
        "        if name == 'jax' or name.startswith('jax.'):\n"
        "            return self\n"
        "    def load_module(self, name):\n"
        "        raise ImportError('jax blocked for this test')\n"
        "sys.meta_path.insert(0, Block())\n"
        "from repro.serving import EngineConfig, Request, ServingEngine\n"
        "eng = ServingEngine(4, pool_tokens=10**5, instances_per_pod=2)\n"
        "eng.register_chunk('c', holder=1, length=2048)\n"
        "recs = eng.schedule_step([Request(0, home=0, chunk_ids=['c'],\n"
        "                                  m_q=64)])\n"
        "assert [r.primitive for r in recs] == ['route'], recs\n"
        "assert 'jax' not in sys.modules\n"
        "print('NO-JAX-PLAN-OK')\n")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env)
    assert out.returncode == 0, out.stderr
    assert "NO-JAX-PLAN-OK" in out.stdout


# ---------------------------------------------------------------------------
# route_batched: the plan-keyed entry point.
# ---------------------------------------------------------------------------

class TestRouteBatched:
    def test_groups_match_route_simulated(self):
        from repro.core.routing import route_batched, route_simulated
        import jax
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (5, TINY_MLA.n_heads, TINY_MLA.d_qk))
        s1 = jax.random.normal(k2, (12, TINY_MLA.d_qk))
        s2 = jax.random.normal(k3, (7, TINY_MLA.d_qk))
        got = route_batched(TINY_MLA, [q, q[:2]], [[s1, s2], [s2]])
        want0 = route_simulated(TINY_MLA, q, [s1, s2])
        np.testing.assert_allclose(got[0].o, want0.o, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(
            got[1].o, absorbed_partial(TINY_MLA, q[:2], s2).o,
            rtol=RTOL, atol=ATOL)

    def test_length_mismatch_raises(self):
        from repro.core.routing import route_batched
        with pytest.raises(ValueError):
            route_batched(TINY_MLA, [jnp.zeros((1, 2, 24))], [])


# ---------------------------------------------------------------------------
# Overlapped execution units (ISSUE 8) — everything here is single-device:
# the fused path's host-side machinery (query memo, stage apportioning,
# report telemetry, pool retirement hooks) without a mesh.
# ---------------------------------------------------------------------------

class TestExecOverlapUnits:
    def test_query_memo_reuses_and_prunes(self):
        from repro.serving.backends import JaxExecBackend
        b = JaxExecBackend()
        rq = Request(3, home=0, chunk_ids=["c"], m_q=4)
        q1 = b.query_of(rq, 1)
        assert b.query_of(rq, 1) is q1            # memo hit, same buffer
        # a different request pinning the SAME query_seed shares the entry
        twin = Request(9, home=1, chunk_ids=["c"], m_q=4, query_seed=3)
        assert b.query_of(twin, 1) is q1
        b.query_of(rq, 2)
        assert (3, 1, 4) in b._qmemo              # previous step retained
        b.query_of(rq, 4)
        assert (3, 1, 4) not in b._qmemo          # ... then pruned
        assert (3, 4, 4) in b._qmemo

    def test_apportion_spreads_wall_over_planned_ratios(self):
        from types import SimpleNamespace
        from repro.serving.backends import ShardMapExecBackend
        b = ShardMapExecBackend()
        rec = SimpleNamespace(stages=[("probe", 1e-6), ("transfer", 3e-6)],
                              req_ids=[0], chunk_id="c", primitive="route")
        meas = b._apportion(rec, 8e-6, {}, 1)
        assert meas["probe"] == pytest.approx(2e-6)
        assert meas["transfer"] == pytest.approx(6e-6)
        assert b._fill_count == 0

    def test_apportion_zero_base_is_counted_fill(self):
        from types import SimpleNamespace
        from repro.serving.backends import ShardMapExecBackend
        b = ShardMapExecBackend()
        rec = SimpleNamespace(stages=[("pull", 0.0), ("splice", 0.0)],
                              req_ids=[0], chunk_id="c", primitive="fetch")
        meas = b._apportion(rec, 4e-6, {}, 1)
        assert meas["pull"] == pytest.approx(2e-6)
        assert meas["splice"] == pytest.approx(2e-6)
        assert b._fill_count == 2                 # the S6 counter, not 0.0s

    def test_apportion_index_stage_uses_selector_measurement(self):
        from types import SimpleNamespace
        from repro.serving.backends import ShardMapExecBackend
        b = ShardMapExecBackend()
        rec = SimpleNamespace(
            stages=[("index", 9e-6), ("probe", 1e-6), ("compute", 1e-6)],
            req_ids=[5], chunk_id="sel", primitive="route")
        meas = b._apportion(rec, 6e-6, {(2, 5, "sel"): 7e-6}, 2)
        assert meas["index"] == pytest.approx(7e-6)   # plan-time wall
        # the fused wall is spread over the NON-index planned ratios only
        assert meas["probe"] == pytest.approx(3e-6)
        assert meas["compute"] == pytest.approx(3e-6)
        assert b._fill_count == 0

    def test_measured_report_telemetry(self):
        import repro.serving.timeline as TL
        flows = [TL.transport_flow(
            "route:c@1#0", [("probe", 1e-6), ("transfer", 2e-6)],
            link_res=TL.link(1, 0), holder_sm=TL.sm(1),
            requester_sm=TL.sm(0), primitive="route", chunk_id="c")]
        ana = TL.simulate(flows)
        rep = TL.measured_vs_analytic(1, ana, flows, 0.5, mode="fused",
                                      pool_entries=2, pool_bytes=64,
                                      stage_fills=1)
        assert (rep.mode, rep.pool_entries, rep.pool_bytes,
                rep.stage_fills) == ("fused", 2, 64, 1)
        head = rep.summary().splitlines()[0]
        assert "makespan analytic" in head      # the CI smoke's grep line
        assert "fused" in head and "pool 2/64B" in head
        assert "1 stage fills" in head
        assert rep.overlap_efficiency == pytest.approx(
            ana.makespan_s / sum(ana.stage_totals().values()))
        # defaults stay backward compatible (the serial path's call)
        bare = TL.measured_vs_analytic(1, ana, flows)
        assert (bare.mode, bare.pool_entries, bare.stage_fills) \
            == ("serial", 0, 0)
        assert "stage fills" not in bare.summary().splitlines()[0]

    def test_measured_overview_aggregates(self):
        import repro.serving.timeline as TL
        eng = ServingEngine(2, pool_tokens=10**5)
        assert eng.measured_overview() is None    # analytic-only run
        flows = [TL.transport_flow(
            "route:c@1#0", [("transfer", 2e-6)], link_res=TL.link(1, 0),
            holder_sm=TL.sm(1), requester_sm=TL.sm(0), primitive="route",
            chunk_id="c")]
        ana = TL.simulate(flows)
        eng.measured_reports = [
            None, TL.measured_vs_analytic(1, ana, flows, 0.1, mode="fused",
                                          pool_entries=3, pool_bytes=96)]
        line = eng.measured_overview()
        assert "ratio p50 x1.0" in line and "fused" in line
        assert "pool 3 entries/96B" in line

    def test_evict_listener_fires_on_evict_and_drop(self):
        from repro.core.chunk_store import ChunkStore
        st = ChunkStore(3, 10**4)
        seen = []
        listener = lambda cid, inst: seen.append((cid, inst))
        st.add_evict_listener(listener)
        st.add_evict_listener(listener)           # idempotent registration
        st.register("c", holder=0, length=8, data=jnp.ones((8, 4)))
        st.add_replica("c", 1)
        st.set_replica_data("c", 1, jnp.ones((8, 4)))
        st.evict_replica("c", 1)
        assert seen == [("c", 1)]                 # fired once, not twice
        st.add_replica("c", 2)
        st.set_replica_data("c", 2, jnp.ones((8, 4)))
        st.drop_holder(0)                         # holder dies, 2 promoted
        assert seen == [("c", 1), ("c", 0)]
