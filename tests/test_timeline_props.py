"""Property tests for the overlap-aware transport timeline (ISSUE 2):

  * makespan >= the most expensive single flow (its independent price);
  * makespan <= the serial sum of every stage (work conservation);
  * no two flows ever overlap on the same (link, fabric) resource — nor
    on any capacity-1 resource (SM occupancy included);
  * stages within a flow run in order, back-pressure respected;
  * a 1-flow timeline exactly equals the scalar cost-model price.

Randomized over flow counts, stage durations, and resource topologies via
hypothesis (dev-only; the module skips without it — requirements-dev.txt)."""

import numpy as np
import pytest

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.serving import timeline as TL

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

durations = st.floats(min_value=1e-7, max_value=1e-2,
                      allow_nan=False, allow_infinity=False)


@st.composite
def flow_sets(draw):
    n = draw(st.integers(min_value=1, max_value=8))
    n_links = draw(st.integers(min_value=1, max_value=3))
    n_insts = draw(st.integers(min_value=1, max_value=4))
    flows = []
    for i in range(n):
        primitive = draw(st.sampled_from(["route", "fetch", "local"]))
        if primitive == "route":
            stages = (("probe", draw(durations)),
                      ("transfer", draw(durations)),
                      ("compute", draw(durations)),
                      ("return", draw(durations)),
                      ("merge", draw(durations)))
        elif primitive == "fetch":
            stages = (("pull", draw(durations)),
                      ("splice", draw(durations)))
        else:
            stages = (("prefill", draw(durations)),)
        link_inst = draw(st.integers(min_value=0, max_value=n_links - 1))
        fabric_idx = draw(st.integers(min_value=0, max_value=1))
        holder = draw(st.integers(min_value=0, max_value=n_insts - 1))
        requester = draw(st.integers(min_value=0, max_value=n_insts - 1))
        flows.append(TL.transport_flow(
            f"{primitive}#{i}", stages,
            link_res=(TL.link(link_inst, fabric_idx)
                      if primitive != "local" else None),
            holder_sm=TL.sm(holder), requester_sm=TL.sm(requester),
            primitive=primitive))
    return flows


@given(flow_sets())
@settings(max_examples=300, deadline=None)
def test_makespan_bracketed_by_max_and_serial_sum(flows):
    t = TL.simulate(flows)
    hardest = max(f.serial_s for f in flows)
    serial = sum(f.serial_s for f in flows)
    assert t.makespan_s >= hardest - 1e-12 * max(1.0, hardest)
    assert t.makespan_s <= serial + 1e-12 * max(1.0, serial)
    assert t.serial_s == pytest.approx(serial, rel=1e-12)


@given(flow_sets())
@settings(max_examples=300, deadline=None)
def test_no_two_flows_overlap_on_any_shared_resource(flows):
    t = TL.simulate(flows)
    by_res = {}
    for s in t.scheduled:
        if s.resource is not None:
            by_res.setdefault(s.resource, []).append(s)
    for res, stages in by_res.items():
        stages.sort(key=lambda s: (s.start_s, s.end_s))
        for a, b in zip(stages, stages[1:]):
            assert b.start_s >= a.end_s - 1e-15, (res, a, b)


@given(flow_sets())
@settings(max_examples=200, deadline=None)
def test_stages_within_a_flow_run_in_order(flows):
    t = TL.simulate(flows)
    by_flow = {}
    for s in t.scheduled:
        by_flow.setdefault(s.flow_key, []).append(s)
    for f in flows:
        got = by_flow[f.key]
        # scheduled in declaration order, each starting after its
        # predecessor finishes
        assert [s.stage for s in got] == [s.name for s in f.stages]
        for a, b in zip(got, got[1:]):
            assert b.start_s >= a.end_s - 1e-15
        assert t.flow_end_s(f.key) == pytest.approx(got[-1].end_s)


@given(st.integers(min_value=1, max_value=8192),
       st.integers(min_value=0, max_value=6),
       st.sampled_from(sorted(C.FABRICS)))
@settings(max_examples=200, deadline=None)
def test_one_flow_timeline_is_the_scalar_price(m_q, k_flows, fabric_name):
    fab = C.fabric(fabric_name)
    f = TL.transport_flow("route#0", cm.route_stages(fab, m_q, k_flows),
                          link_res=TL.link(0, 0), holder_sm=TL.sm(0),
                          requester_sm=TL.sm(1))
    t = TL.simulate([f])
    want = cm.t_route_congested_full(fab, m_q, k_flows)
    np.testing.assert_allclose(t.makespan_s, want, rtol=1e-9)
    assert t.overlap_efficiency == pytest.approx(1.0, rel=1e-9)


@given(st.integers(min_value=1, max_value=16384),
       st.integers(min_value=1, max_value=100_000),
       st.sampled_from(sorted(C.FABRICS)))
@settings(max_examples=200, deadline=None)
def test_one_fetch_flow_is_the_amortised_scalar_price(c_t, reuse,
                                                      fabric_name):
    fab = C.fabric(fabric_name)
    f = TL.transport_flow("fetch#0",
                          cm.fetch_stages(fab, c_t, reuse_steps=reuse),
                          link_res=TL.link(0, 0), holder_sm=TL.sm(0),
                          requester_sm=TL.sm(1))
    t = TL.simulate([f])
    np.testing.assert_allclose(t.makespan_s, cm.t_fetch(fab, c_t) / reuse,
                               rtol=1e-9)
