"""Golden regression tests for the serving engine (ISSUE 2): three small
frozen traces — routed-only, fetch-heavy, mixed congested — with decision
sequences AND per-step stage breakdowns asserted against checked-in JSON
fixtures (tests/fixtures/). A cost-model or scheduler refactor that shifts
the route/fetch crossover, the §8 occupancy-derived congestion premium, or
the timeline's stage anatomy fails loudly here instead of silently moving
the paper's numbers.

Everything asserted is simulated (deterministic closed forms + the
deterministic greedy timeline) — scheduler wall-clock never enters a
fixture. Floats compare at rel 1e-9, loose enough for cross-platform
libm/ulp drift, tight enough that any real model change trips it.

Regenerate after an INTENTIONAL model change (then eyeball the diff):

    PYTHONPATH=src python tests/test_engine_golden.py
"""

import json
import pathlib

import pytest

# the frozen scenarios live in engine_scenarios.py, shared with the
# backend parity/exactness suite (tests/test_backends.py)
from engine_scenarios import SCENARIOS

FIXTURES = pathlib.Path(__file__).parent / "fixtures"
REL_TOL = 1e-9


# ---------------------------------------------------------------------------
# Snapshot + comparison.
# ---------------------------------------------------------------------------

def snapshot(build) -> dict:
    eng, steps = build()
    out = {"steps": []}
    for reqs in steps:
        recs = eng.schedule_step(reqs)
        s = eng.stats[-1]
        out["steps"].append({
            "decisions": [
                {"primitive": r.primitive, "chunk": r.chunk_id,
                 "holder": r.holder, "n_requesters": r.n_requesters,
                 "m_q_total": r.m_q_total, "backup": r.backup,
                 "est_cost_s": r.est_cost_s,
                 "stages": [[n, d] for n, d in r.stages]}
                for r in recs],
            "primitives": s.primitives,
            "n_resident": s.n_resident,
            "latency_s": s.latency_s,
            "max_dispatch_s": s.max_dispatch_s,
            "serial_stage_s": s.serial_stage_s,
            "stage_totals": s.stage_totals,
            "has_transport": s.has_transport,
        })
    return out


def _assert_close(got, want, path):
    if isinstance(want, float) and isinstance(got, (int, float)):
        assert got == pytest.approx(want, rel=REL_TOL), \
            f"{path}: {got} != {want}"
    elif isinstance(want, dict):
        assert isinstance(got, dict) and sorted(got) == sorted(want), \
            f"{path}: keys {sorted(got)} != {sorted(want)}"
        for k in want:
            _assert_close(got[k], want[k], f"{path}.{k}")
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), \
            f"{path}: length {len(got)} != {len(want)}"
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_close(g, w, f"{path}[{i}]")
    else:
        assert got == want, f"{path}: {got!r} != {want!r}"


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_trace(name):
    fixture = FIXTURES / f"engine_{name}.json"
    assert fixture.exists(), \
        f"missing fixture {fixture}; regenerate: python {__file__}"
    want = json.loads(fixture.read_text())
    got = snapshot(SCENARIOS[name])
    _assert_close(got, want, name)


def test_fixture_sanity():
    """The frozen traces cover what they claim: primitives, congestion,
    and an empty (fully-resident) step."""
    routed = json.loads((FIXTURES / "engine_routed_only.json").read_text())
    assert all(d["primitive"] == "route"
               for s in routed["steps"] for d in s["decisions"])

    fetchy = json.loads((FIXTURES / "engine_fetch_heavy.json").read_text())
    assert any(d["primitive"] == "fetch"
               for d in fetchy["steps"][0]["decisions"])
    assert not fetchy["steps"][-1]["has_transport"]
    assert fetchy["steps"][-1]["latency_s"] == 0.0

    mixed = json.loads(
        (FIXTURES / "engine_mixed_congested.json").read_text())
    prims = {d["primitive"] for s in mixed["steps"] for d in s["decisions"]}
    assert {"route", "fetch", "local"} <= prims
    # 4 flows share holder 1's link in step 1: the makespan strictly
    # exceeds the old independent max-reduce price
    s0 = mixed["steps"][0]
    assert s0["latency_s"] > s0["max_dispatch_s"]


if __name__ == "__main__":
    FIXTURES.mkdir(exist_ok=True)
    for name, build in sorted(SCENARIOS.items()):
        path = FIXTURES / f"engine_{name}.json"
        path.write_text(json.dumps(snapshot(build), indent=1) + "\n")
        print(f"wrote {path}")
