"""Wrappers that run multi-device shard_map programs in subprocesses (the
main pytest process must keep 1 CPU device; the progs force 8/16)."""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
PROGS = ROOT / "tests" / "progs"


def run_prog(name: str, timeout=900, expect: str = "OK"):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(PROGS / name)],
                       capture_output=True, text=True, timeout=timeout,
                       cwd=str(ROOT), env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert expect in r.stdout, r.stdout
    return r.stdout


def test_distributed_routing():
    """§3.3 exactness on a real 8-device mesh: fanout / ring / pairwise /
    TPLA rank-pairing (+ §8 per-rank byte reduction from compiled HLO)."""
    run_prog("dist_routing_prog.py", expect="DIST-ROUTING-OK")


def test_distributed_substrates():
    """Elastic checkpoint across mesh shapes, int8 error-feedback
    compressed DP parity, collective-matmul overlap correctness."""
    run_prog("dist_substrate_prog.py", expect="DIST-SUBSTRATE-OK")


def test_shard_map_exec_backend():
    """ISSUE 7: the ShardMapExecBackend runs every golden scenario + the
    selection trace on an 8-device mesh with real collectives — oracle
    exactness, analytic StepStats parity, measured-vs-analytic reports,
    mesh-indexer verdict parity, exec-mode failover, shard validation."""
    run_prog("shard_map_exec_prog.py", timeout=1200,
             expect="SHARD-MAP-EXEC-OK")


def test_distributed_dryrun_machinery():
    """build_lowered -> compile -> roofline extraction on small real
    meshes, incl. the multi-pod pod axis actually sharding."""
    run_prog("dist_dryrun_prog.py", timeout=1200, expect="DIST-DRYRUN-OK")
