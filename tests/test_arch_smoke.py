"""Per-architecture smoke tests (task spec): a REDUCED same-family config
runs one forward + one train-grad step + one decode step on CPU, asserting
output shapes and no NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as MD
from repro.models.module import count_params, split


def make_batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(k, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            k, (B, cfg.vlm_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.1 * jax.random.normal(
            k, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def _setup(self, arch):
        cfg = get_smoke_config(arch)
        params, _ = split(MD.init_model(cfg, jax.random.PRNGKey(0)))
        return cfg, params

    def test_forward_shapes_and_finite(self, arch):
        cfg, params = self._setup(arch)
        B, S = 2, 16
        batch = make_batch(cfg, B, S)
        logits, _, aux = MD.forward(params, cfg, batch)
        exp_s = S if cfg.family != "vlm" else S
        assert logits.shape == (B, exp_s, cfg.vocab), logits.shape
        assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))

    def test_train_grad_step(self, arch):
        cfg, params = self._setup(arch)
        batch = make_batch(cfg, 2, 16)
        loss, grads = jax.value_and_grad(
            lambda p: MD.loss_fn(p, cfg, batch))(params)
        assert np.isfinite(float(loss))
        flat = jax.tree.leaves(grads)
        assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
                   for g in flat)
        # loss must move under a gradient step (the model actually learns)
        lr = 1e-2
        p2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                          params, grads)
        loss2 = MD.loss_fn(p2, cfg, batch)
        assert float(loss2) != float(loss)

    def test_decode_step(self, arch):
        cfg, params = self._setup(arch)
        B, S = 2, 16
        state = MD.init_decode_state(cfg, B, S)
        token = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.full((B, 1), S, jnp.int32)
        logits, new_state = MD.decode_step(params, cfg, state, token, pos,
                                           jnp.int32(0))
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
        # state structure preserved
        assert (jax.tree.structure(new_state) == jax.tree.structure(state))


class TestFullConfigMetadata:
    """The full configs must carry the exact published geometry."""

    def test_param_counts_in_band(self):
        # abstract init (no allocation): check total params are in the
        # right ballpark for the headline sizes.
        import functools
        expected = {
            "qwen1_5_32b": (30e9, 36e9),
            "qwen2_5_32b": (30e9, 36e9),
            "qwen3_32b": (30e9, 36e9),
            "nemotron_4_340b": (320e9, 360e9),
            "deepseek_v2_236b": (220e9, 250e9),
            "qwen3_moe_235b": (220e9, 250e9),
            "llava_next_mistral_7b": (6.5e9, 7.8e9),
            "zamba2_7b": (6.0e9, 9.0e9),
            "mamba2_370m": (0.3e9, 0.45e9),
            "whisper_large_v3": (1.4e9, 1.8e9),
        }
        for arch, (lo, hi) in expected.items():
            cfg = get_config(arch)
            shapes = jax.eval_shape(
                functools.partial(MD.init_model, cfg),
                jax.random.PRNGKey(0))
            vals, _ = split(shapes)
            n = count_params(vals)
            assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params"

    def test_deepseek_payload_matches_paper(self):
        cfg = get_config("deepseek_v2_236b")
        assert cfg.mla.d_qk == 576
        assert cfg.kv_bytes_token_layer == 1152
        lite = get_config("deepseek-v2-lite")
        assert lite.n_layers == 27 and lite.mla.d_qk == 576
