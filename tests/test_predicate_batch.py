"""decide_batch() must agree with the scalar decide() element-wise — the
vectorized scheduler path is only trustworthy if it IS the paper's predicate
(§5), just evaluated in bulk. Fuzzes >= 1000 randomized (m_q, c_t, fabric,
reuse, selection, delta, compute/host flags) points plus directed edges."""

import numpy as np
import pytest

from repro.core import constants as C
from repro.core import cost_model as cm
from repro.core import predicate as P


def _random_requests(rng: np.random.RandomState, n: int):
    fabric_names = sorted(C.FABRICS)
    reqs = []
    for _ in range(n):
        sel = rng.rand() < 0.3
        reqs.append(P.Request(
            m_q=int(rng.randint(1, 8192)),
            c_t=int(rng.randint(1, 16384)),
            fabric=C.fabric(fabric_names[rng.randint(len(fabric_names))]),
            expected_reuse_steps=int(rng.choice([1, 1, 2, 8, 100, 100_000])),
            k_selected=int(rng.choice([512, 1024, 2048])) if sel else None,
            n_holders=int(rng.randint(1, 9)),
            position_delta=int(rng.choice([0, 0, 1, 17, 100_000])),
            holder_can_compute=bool(rng.rand() < 0.9),
            host_overhead=bool(rng.rand() < 0.2)))
    return reqs


class TestBatchAgreesWithScalar:
    def test_randomized_1000_points(self):
        rng = np.random.RandomState(0)
        reqs = _random_requests(rng, 1200)
        batch = P.RequestBatch.from_requests(reqs)
        dec = P.decide_batch(batch)
        for i, r in enumerate(reqs):
            want = P.decide(r)
            assert dec.primitive(i) is want.primitive, (i, r)
            np.testing.assert_allclose(dec.t_route[i], want.t_route,
                                       rtol=1e-12)
            np.testing.assert_allclose(dec.t_fetch[i], want.t_fetch,
                                       rtol=1e-12)
            np.testing.assert_allclose(dec.t_local[i], want.t_local,
                                       rtol=1e-12)

    def test_directed_edges(self):
        ib = C.fabric("h100_ibgda")
        edges = [
            # the §5.5 rules of thumb, one per regime
            P.Request(m_q=256, c_t=2048, fabric=ib),                 # ROUTE
            P.Request(m_q=1, c_t=2048, fabric=ib,
                      expected_reuse_steps=100_000),                 # FETCH
            P.Request(m_q=1, c_t=30, fabric=ib,
                      holder_can_compute=False),                     # LOCAL
            P.Request(m_q=256, c_t=2048, fabric=ib,
                      k_selected=2048, n_holders=7),                 # §5.4
            P.Request(m_q=256, c_t=2048, fabric=ib, position_delta=0,
                      host_overhead=True),                           # §5.3
            P.Request(m_q=256, c_t=2048, fabric=ib, k_selected=2048,
                      n_holders=1),        # selection, single holder
        ]
        batch = P.RequestBatch.from_requests(edges)
        dec = P.decide_batch(batch)
        for i, r in enumerate(edges):
            assert dec.primitive(i) is P.decide(r).primitive, r

    def test_empty_batch(self):
        batch = P.RequestBatch.from_requests([])
        dec = P.decide_batch(batch)
        assert len(batch) == 0 and dec.code.shape == (0,)

    def test_mixed_payload_rejected(self):
        other = cm.payload_for(d_qk=128, d_v=128, n_layers=32)
        with pytest.raises(ValueError):
            P.RequestBatch.from_requests([
                P.Request(m_q=1, c_t=10, fabric=C.fabric("tpu_ici")),
                P.Request(m_q=1, c_t=10, fabric=C.fabric("tpu_ici"),
                          payload=other)])


class TestOccupancyFedCongestion:
    """The engine now feeds decide_batch a k_flows DERIVED from observed
    link occupancy (serving.engine._occupancy_k_flows) rather than assumed
    group counts. Whatever produced the array, decide_batch under k_flows
    must still be the scalar predicate with ROUTE re-priced by the §8
    closed form — fuzz the occupancy-fed branch element-wise."""

    @staticmethod
    def _scalar_route_congested(r: P.Request, k: int) -> float:
        # mirrors route_cost_batch's k_flows branch in scalar form
        if not r.holder_can_compute:
            return float("inf")
        t_host = (C.HOST_OVERHEAD_BASE_S + C.HOST_OVERHEAD_PER_ROW_S * r.m_q
                  if r.host_overhead else 0.0)
        if r.k_selected is not None and r.n_holders > 1:
            # fan-out sends are probe-bound and concurrent: the §8 single-
            # link premium does not apply (matches the batch np.where)
            return cm.t_route_fanout(r.fabric, r.m_q, r.n_holders,
                                     r.payload) + t_host
        return cm.t_route_congested_full(r.fabric, r.m_q, k,
                                         r.payload) + t_host

    def test_fuzzed_600_points_match_scalar_reference(self):
        rng = np.random.RandomState(7)
        reqs = _random_requests(rng, 600)
        k_flows = rng.randint(0, 9, size=len(reqs)).astype(np.int64)
        batch = P.RequestBatch.from_requests(reqs)
        dec = P.decide_batch(batch, k_flows)
        for i, r in enumerate(reqs):
            tr = self._scalar_route_congested(r, int(k_flows[i]))
            tf, tl = P.fetch_cost(r), P.local_cost(r)
            want = min((tr, P.Primitive.ROUTE), (tf, P.Primitive.FETCH),
                       (tl, P.Primitive.LOCAL), key=lambda x: x[0])[1]
            assert dec.primitive(i) is want, (i, r, int(k_flows[i]))
            if np.isfinite(tr):
                np.testing.assert_allclose(dec.t_route[i], tr, rtol=1e-12)
            np.testing.assert_allclose(dec.t_fetch[i], tf, rtol=1e-12)
            np.testing.assert_allclose(dec.t_local[i], tl, rtol=1e-12)

    def test_congestion_can_flip_route_to_fetch(self):
        # the §8 point the engine's feedback loop relies on: enough observed
        # flows on the link and the predicate itself re-routes to FETCH
        ib = C.fabric("h100_ibgda")
        r = P.Request(m_q=2048, c_t=1024, fabric=ib,
                      expected_reuse_steps=10)
        batch = P.RequestBatch.from_requests([r, r])
        dec = P.decide_batch(batch, np.array([1, 24]))
        assert dec.primitive(0) is P.Primitive.ROUTE
        assert dec.primitive(1) is P.Primitive.FETCH

    def test_zero_flows_matches_uncontended(self):
        # k_flows=0 (a link nobody transports on) must price exactly like
        # the uncontended path
        rng = np.random.RandomState(11)
        reqs = _random_requests(rng, 64)
        batch = P.RequestBatch.from_requests(reqs)
        got = P.decide_batch(batch, np.zeros(len(reqs), np.int64))
        want = P.decide_batch(batch, None)
        np.testing.assert_allclose(got.t_route, want.t_route, rtol=1e-12)
        np.testing.assert_array_equal(got.code, want.code)


class TestCongestedPricing:
    def test_kflows_flat_through_2_then_rises(self):
        ib = C.fabric("h100_ibgda")
        reqs = [P.Request(m_q=1024, c_t=2048, fabric=ib) for _ in range(3)]
        batch = P.RequestBatch.from_requests(reqs)
        t = P.route_cost_batch(batch, k_flows=np.array([1, 2, 3]))
        assert t[1] == pytest.approx(t[0], rel=1e-9)
        # §8: +119% on transport at K=3 => >1.5x even with the flat
        # compute+merge terms folded in
        assert t[2] > 1.5 * t[1]

    def test_congested_matches_scalar_congested(self):
        ib = C.fabric("h100_ibgda")
        for k in (0, 1, 2, 3, 5):
            reqs = [P.Request(m_q=512, c_t=2048, fabric=ib)]
            batch = P.RequestBatch.from_requests(reqs)
            got = P.route_cost_batch(batch, k_flows=np.array([k]))[0]
            want = (cm.t_route_congested(ib, 512, k)
                    + np.mean(C.HOLDER_COMPUTE_DECODE_S) + C.MERGE_COST_S)
            np.testing.assert_allclose(got, want, rtol=1e-12)
