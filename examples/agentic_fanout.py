"""The agentic workload (§1, §6.3): one large immutable document pinned as
a prefix, N concurrent sub-agents fork it copy-on-write, append private
suffixes, and every decode step attends the shared c^KV.

Demonstrates, with REAL attention math (single-host simulation of the
instance mesh):
  * CoW forks: shared prefix + private suffix per agent;
  * per-step routed decode: each agent's query merges a partial from the
    document holder with its own suffix partial — exact vs a monolithic
    cache (§3.3);
  * the replication decision at the N~8 elbow: fan_in(chunk) drives the
    engine's replica spawn (the amortised-FETCH boundary, not the splice,
    governs the pure-prefix case — §6.3).

    PYTHONPATH=src python examples/agentic_fanout.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.merge import merge2
from repro.models import mla as M
from repro.models.module import KeyGen, split
from repro.serving.engine import Request, ServingEngine

CFG = M.MLAConfig(d_model=256, n_heads=8, kv_lora_rank=64,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
DOC_TOKENS = 512
N_AGENTS = 12


def main():
    params, _ = split(M.init_mla(KeyGen(jax.random.PRNGKey(0)), CFG,
                                 dtype=jnp.float32))
    # the pinned document, prefilled once at canonical offset 0
    doc = 0.1 * jax.random.normal(jax.random.PRNGKey(1),
                                  (1, DOC_TOKENS, CFG.d_model))
    doc_pos = jnp.arange(DOC_TOKENS)[None]
    doc_ckv = M.latent_cache_entries(params, CFG, doc, doc_pos)[0]

    eng = ServingEngine(n_instances=8, pool_tokens=1_000_000,
                        instances_per_pod=4)
    eng.register_chunk("pinned_codebase", holder=0, length=DOC_TOKENS)

    print(f"document: {DOC_TOKENS} tokens on instance 0; "
          f"{N_AGENTS} sub-agents fork it CoW")
    errs = []
    for a in range(N_AGENTS):
        fork = eng.store.fork("pinned_codebase", agent_instance=a % 8)
        # agent appends a private suffix (true prefix: delta = 0, the
        # splice elides — §6.3)
        suffix_len = 16 + 4 * a
        eng.store.append_suffix(fork.fork_id, suffix_len)
        sx = 0.1 * jax.random.normal(jax.random.PRNGKey(10 + a),
                                     (1, suffix_len, CFG.d_model))
        spos = DOC_TOKENS + jnp.arange(suffix_len)[None]
        suffix_ckv = M.latent_cache_entries(params, CFG, sx, spos)[0]

        # one decode step: query at the tail of the agent's fork
        qn, qr = M.project_q(params, CFG, sx[:, -1:], spos[:, -1:] + 1)
        q_abs = M.absorb_query(params, CFG, qn, qr)[:, 0]

        # routed: holder partial over the doc + local partial over suffix
        p_doc = M.absorbed_partial(CFG, q_abs, doc_ckv)       # at holder
        p_suf = M.absorbed_partial(CFG, q_abs, suffix_ckv)    # at agent
        merged = merge2(p_suf, p_doc)
        # oracle: one monolithic cache
        mono = M.absorbed_partial(
            CFG, q_abs, jnp.concatenate([doc_ckv, suffix_ckv], axis=0))
        errs.append(float(jnp.max(jnp.abs(merged.o - mono.o))))

    print(f"routed fork decode vs monolithic cache, {N_AGENTS} agents: "
          f"max|err| = {max(errs):.2e} (fp32 round-off)")
    assert max(errs) < 1e-5

    fan = eng.store.fan_in("pinned_codebase")
    print(f"fan-in on the pinned document: {fan} concurrent readers")
    print(f"replicate beyond the elbow? "
          f"{P.replication_threshold(fan)} (elbow N={P.holder_fanout_cap()})")

    # drive the engine over MULTIPLE steps with all agents hammering the
    # doc: step 1 caps fan-in at the elbow and spawns a replica (amortised
    # FETCH); later steps see the replica resident and rebalance onto it
    reqs = [Request(req_id=a, home=(a % 7) + 1,
                    chunk_ids=["pinned_codebase"],
                    expected_reuse_steps=8) for a in range(N_AGENTS)]
    for _ in range(3):
        eng.schedule_step(reqs)
        s = eng.stats[-1]
        print(f"engine step {s.step}: dispatches {s.primitives}, "
              f"{s.n_resident}/{s.n_pairs} resident, "
              f"critical path {s.latency_s*1e6:.0f}us")
    print(f"holders now: {eng.store.holders_of('pinned_codebase')} "
          f"(replica persisted past the N~{eng.cfg.fanin_cap} elbow)")


if __name__ == "__main__":
    main()
