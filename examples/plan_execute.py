"""Plan / execute / account, end to end (ISSUE 3).

ONE agentic trace drives the serving engine twice:

  1. AnalyticBackend — the planner's dispatch plans scheduled on the
     overlap-aware transport timeline (pure simulation, paper constants);
  2. JaxExecBackend — the SAME plans executed on real c^KV arrays:
     ROUTE ships the grouped query rows to the holder's copy, FETCH
     replicates the chunk through the delta-0 splice then serves locally,
     LOCAL re-prefills — and every request's merged output is checked
     against single-instance attention over its concatenated chunks
     (the paper's §3.3 exactness claim, now THROUGH the scheduler).

    PYTHONPATH=src python examples/plan_execute.py
"""

from repro.serving import (AnalyticBackend, EngineConfig, JaxExecBackend,
                           ServingEngine, WorkloadConfig, agentic_trace,
                           materialize_trace, register_corpus)
from repro.serving.backends.jax_exec import max_oracle_err


def build(backend):
    eng = ServingEngine(n_instances=8, pool_tokens=48 * 256,
                        cfg=EngineConfig(), instances_per_pod=4,
                        backend=backend)
    wl = WorkloadConfig(n_steps=12, agents=12, n_corpus_chunks=10,
                        chunk_tokens=256, session_steps=(3, 10), seed=1)
    cids = register_corpus(eng, wl)
    return eng, materialize_trace(agentic_trace(wl, eng, cids))


def main():
    ana, steps = build(AnalyticBackend())
    exe, _ = build(JaxExecBackend())

    print("=== one trace, two backends "
          "(plan is shared; execute is pluggable) ===")
    for reqs in steps:
        ana.schedule_step(reqs)
        exe.schedule_step(reqs)
        sa, se = ana.stats[-1], exe.stats[-1]
        # planner parity: identical decisions, identical analytic costs
        assert sa.primitives == se.primitives
        assert sa.latency_s == se.latency_s
        # exec exactness: outputs == single-instance attention (§3.3)
        worst = max_oracle_err(exe, reqs, exe.step_idx)
        print(f"step {se.step:>2}: {se.n_dispatches} dispatches "
              f"{se.primitives}, {se.n_resident}/{se.n_pairs} resident, "
              f"makespan {se.latency_s*1e6:.0f}us | exec max|err| "
              f"{worst:.2e}")

    routes = sum(1 for r in exe.log if r.primitive == "route")
    fetches = sum(1 for r in exe.log
                  if r.primitive in ("fetch", "fetch_replica"))
    print(f"\n{len(exe.log)} dispatches executed on real arrays: "
          f"{routes} routed (query moved), {fetches} fetched (cache "
          f"moved + spliced); decisions identical across backends — the "
          f"predicate picked, both layers obeyed, outputs exact.")


if __name__ == "__main__":
    main()
