"""End-to-end training driver: a ~100M-param MLA+MoE transformer (the
paper's architecture family) trained for a few hundred steps on CPU with
the full production stack: deterministic pipeline, grad-accumulation train
step, AdamW, async checkpointing, fault-tolerant loop (one induced failure
mid-run proves restore+replay).

    PYTHONPATH=src python examples/train_mla_100m.py [--steps 200]
"""

import argparse
import pathlib
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticPipeline
from repro.models import model as MD
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.module import count_params, split
from repro.optim.adamw import AdamWConfig, adamw_init, cosine_schedule
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainConfig, make_train_step


def build_config(full: bool) -> MD.ModelConfig:
    """full=True: ~100M params (deepseek-v2-lite family scaled down) — the
    task-spec driver, a few hundred steps (budget ~1 CPU-hour on this box).
    Default: a ~20M variant that finishes in minutes on one CPU core; the
    architecture and stack are identical."""
    if full:
        return MD.ModelConfig(
            name="mla-100m", family="moe", n_layers=8, d_model=512,
            vocab=32768, attn_type="mla", n_heads=8, n_kv_heads=8,
            mla=MLAConfig(d_model=512, n_heads=8, kv_lora_rank=128,
                          q_lora_rank=None, qk_nope_head_dim=64,
                          qk_rope_head_dim=32, v_head_dim=64),
            d_ff=2048, first_k_dense=1,
            moe=MoEConfig(d_model=512, d_expert=512, n_experts=8, top_k=2,
                          n_shared=1),
            loss_chunk=256,
        )
    return MD.ModelConfig(
        name="mla-20m", family="moe", n_layers=4, d_model=256,
        vocab=8192, attn_type="mla", n_heads=4, n_kv_heads=4,
        mla=MLAConfig(d_model=256, n_heads=4, kv_lora_rank=64,
                      q_lora_rank=None, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32),
        d_ff=1024, first_k_dense=1,
        moe=MoEConfig(d_model=256, d_expert=256, n_experts=8, top_k=2,
                      n_shared=1),
        loss_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="the ~100M config (budget ~1 CPU-hour)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = build_config(args.full)
    params, _ = split(MD.init_model(cfg, jax.random.PRNGKey(0)))
    print(f"model: {cfg.name}, {count_params(params)/1e6:.1f}M params")

    ocfg = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params, ocfg)
    lr_fn = cosine_schedule(1e-3, warmup=20, total=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg, TrainConfig(n_micro=2),
                                      lr_fn))
    pipe = SyntheticPipeline.for_model(cfg, seq_len=args.seq,
                                       global_batch=args.batch)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="mla100m_")
    ckpt = CheckpointManager(ckpt_dir)

    fired = {"done": False}

    def induced_fault(step):
        if step == args.steps // 2 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("induced mid-run node failure")

    t0 = time.time()
    params, opt_state, log = train_loop(
        step_fn, params, opt_state, pipe, ckpt,
        LoopConfig(total_steps=args.steps, ckpt_every=25, log_every=10),
        fault_hook=induced_fault)
    dt = time.time() - t0

    losses = [(e["step"], e["loss"]) for e in log if "loss" in e]
    events = [e for e in log if e.get("event")]
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({args.steps/dt:.2f} steps/s on CPU)")
    print(f"loss: {losses[0][1]:.3f} -> {losses[-1][1]:.3f} "
          f"(first -> last)")
    print(f"fault events: {events}")
    assert losses[-1][1] < losses[0][1], "loss must decrease"
    assert any(e.get("event") == "restored" for e in log), \
        "the induced failure must have triggered a restore"
    print(f"checkpoints at {ckpt_dir}: steps {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
