"""Quickstart: the paper in 60 seconds on CPU.

1. Build a small MLA model and prefill a canonical chunk into latent c^KV.
2. Partition the cache across simulated instances.
3. Route a decode query: partial attention per holder + online-softmax
   merge == single-instance attention (the §3.3 exactness).
4. Ask the closed-form predicate which primitive a scheduler should use.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import predicate as P
from repro.core.routing import route_simulated
from repro.kernels.mla_decode import mla_decode
from repro.models import mla as M
from repro.models.module import KeyGen, split


def main():
    cfg = M.MLAConfig(d_model=256, n_heads=8, kv_lora_rank=64,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32)
    params, _ = split(M.init_mla(KeyGen(jax.random.PRNGKey(0)), cfg,
                                 dtype=jnp.float32))

    # 1. prefill a 256-token canonical chunk into latent cache entries
    S = 256
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model))
    pos = jnp.arange(S)[None]
    ckv = M.latent_cache_entries(params, cfg, x, pos)[0]
    print(f"canonical c^KV: {ckv.shape} ({ckv.size * 2} bytes bf16/entry-row "
          f"= the 'cache' side of the byte asymmetry)")

    # 2. a decode query in absorbed form — the 1-KB wire object
    qn, qr = M.project_q(params, cfg, x[:, -1:], pos[:, -1:] + 1)
    q_abs = M.absorb_query(params, cfg, qn, qr)[:, 0]
    print(f"absorbed query row: {q_abs.shape[-1]} wide "
          f"(DeepSeek-V2 geometry would be 576 = 1152 B)")

    # 3. route across 4 simulated instances and merge — exact
    full = M.absorbed_partial(cfg, q_abs, ckv)
    shards = [ckv[i * 64:(i + 1) * 64] for i in range(4)]
    merged = route_simulated(cfg, q_abs, shards)
    err = float(jnp.max(jnp.abs(merged.o - full.o)))
    print(f"4-holder route+merge vs single-instance: max|err| = {err:.2e}")

    # 3b. the same partial from the Pallas kernel (TPU target, interpreted)
    part = mla_decode(q_abs[None] if q_abs.ndim == 2 else q_abs,
                      ckv[None], d_v=cfg.kv_lora_rank, scale=cfg.scale,
                      block_s=64)
    err_k = float(jnp.max(jnp.abs(part.o[0] - full.o)))
    print(f"Pallas mla_decode kernel vs oracle:        max|err| = {err_k:.2e}")

    # 4. what should the scheduler do? (paper constants, H100 IBGDA)
    for m_q, reuse in ((256, 1), (256, 10_000), (1, 1)):
        d = P.decide(P.Request(m_q=m_q, c_t=2048,
                               fabric=C.fabric("h100_ibgda"),
                               expected_reuse_steps=reuse))
        print(f"M_q={m_q:>4} reuse={reuse:>6}: {d.primitive.value:<6} "
              f"(route {d.t_route*1e6:7.1f}us | fetch {d.t_fetch*1e6:9.1f}us "
              f"| local {d.t_local*1e6:9.1f}us) — {d.reason}")


if __name__ == "__main__":
    main()
