"""Serving driver: a partitioned canonical c^KV store served with the
predicate-driven engine (§5 consumed end-to-end).

Scenario (the paper's §1): a provider pre-prefills canonical chunks (case
law, annual reports) across 8 instances in 2 pods; tenants' decode steps
attend chunks that mostly live on OTHER instances. Watch the engine pick
ROUTE for decode, spawn a replica (amortised FETCH) when fan-in passes the
N~8 elbow, fire straggler backups, and survive a holder failure.

    PYTHONPATH=src python examples/serve_routed.py
"""

import numpy as np

from repro.serving.engine import Request, ServingEngine, transport_latencies
from repro.serving.workload import WorkloadConfig, agentic_trace


def main():
    rng = np.random.RandomState(0)
    eng = ServingEngine(n_instances=8, pool_tokens=64 * 2048,
                        instances_per_pod=4)

    # canonical corpus: 12 chunks spread across instances
    chunks = []
    for i in range(12):
        cid = f"annual_report_{2014 + i}"
        eng.register_chunk(cid, holder=i % 8, length=2048)
        chunks.append(cid)

    print("=== steady-state decode: tenant sessions fan out (multi-step) ===")
    wl = WorkloadConfig(n_steps=24, agents=16, n_corpus_chunks=12,
                        session_steps=(8, 24), seed=0)
    # reuse the already-registered corpus ids as the working-set universe
    stats = eng.run(agentic_trace(wl, eng, chunks))
    for s in stats[:3] + stats[-2:]:
        print(f"step {s.step:>3}: {s.n_dispatches} dispatches "
              f"{s.primitives}, {s.n_resident}/{s.n_pairs} resident, "
              f"makespan {s.latency_s*1e6:.0f}us "
              f"(max-reduce {s.max_dispatch_s*1e6:.0f}us, overlap eff "
              f"{s.overlap_efficiency:.2f})")
    lat = transport_latencies(stats)     # empty steps carry no latency
    resident = sum(s.n_resident for s in stats[-8:]) / \
        max(1, sum(s.n_pairs for s in stats[-8:]))
    print(f"{len(stats)} steps: p50 {np.percentile(lat, 50)*1e6:.0f}us, "
          f"p99 {np.percentile(lat, 99)*1e6:.0f}us; steady residency "
          f"{resident:.0%} (fetches persisted + replicas spawned: "
          f"{sum(s.replicas_spawned for s in stats)})")

    last = stats[-1]
    print(f"\n=== step {last.step} stage Gantt (wire serializes per "
          f"(link, fabric); independent stages overlap) ===")
    print(eng.timeline_of(last.step).gantt(max_flows=8))
    anatomy = " ".join(f"{k}={v*1e6:.0f}us"
                       for k, v in sorted(last.stage_totals.items()))
    print(f"  stage totals: {anatomy}\n  sum-of-stages "
          f"{last.serial_stage_s*1e6:.0f}us -> makespan "
          f"{last.latency_s*1e6:.0f}us "
          f"(overlap efficiency {last.overlap_efficiency:.2f})")

    print("\n=== hot chunk: 20 tenants hammer one document (§6.3) ===")
    hot = chunks[0]
    reqs = [Request(req_id=100 + t, home=(t % 7) + 1, chunk_ids=[hot], m_q=8)
            for t in range(20)]
    recs = eng.schedule_step(reqs)
    for r in recs:
        print(f"  {r.primitive:>14} holder={r.holder} n_req={r.n_requesters}"
              f" m_q={r.m_q_total} est={r.est_cost_s*1e6:.0f}us")
    print(f"  holders of {hot} now: {eng.store.holders_of(hot)} "
          f"(replica spawned past the fan-in cap of "
          f"{eng.cfg.fanin_cap})")

    print("\n=== straggler: instance 2 runs 5x slow ===")
    eng.set_straggler(2, 5.0)
    victim = [c for c in chunks if eng.store.lookup(c).holder == 2][0]
    eng.store.add_replica(victim, 5)
    recs = eng.schedule_step([Request(200, home=0, chunk_ids=[victim],
                                      m_q=16)])
    for r in recs:
        tag = " (backup)" if r.backup else ""
        print(f"  {r.primitive:>14} holder={r.holder} "
              f"est={r.est_cost_s*1e6:.0f}us{tag}")
    print(f"  step makespan {eng.step_latency(eng.step_idx)*1e6:.0f}us "
          f"(backup capped the straggler)")

    print("\n=== holder failure: instance 3 dies ===")
    orphaned = eng.fail_instance(3)
    print(f"  orphaned chunks (re-prefill via LOCAL): {orphaned}")
    live = [i.idx for i in eng.instances if i.alive]
    reqs = [Request(300 + t, home=int(rng.choice(live)),
                    chunk_ids=list(rng.choice(chunks, 2, replace=False)))
            for t in range(6)]
    recs = eng.schedule_step(reqs)
    assert all(r.holder != 3 for r in recs)
    print(f"  step after failure: {len(recs)} dispatches, none to the dead "
          f"instance; primitives used: {sorted({r.primitive for r in recs})}")


if __name__ == "__main__":
    main()
