"""Serving driver: a partitioned canonical c^KV store served with the
predicate-driven engine (§5 consumed end-to-end).

Scenario (the paper's §1): a provider pre-prefills canonical chunks (case
law, annual reports) across 8 instances in 2 pods; tenants' decode steps
attend chunks that mostly live on OTHER instances. Watch the engine pick
ROUTE for decode, spawn a replica (amortised FETCH) when fan-in passes the
N~8 elbow, fire straggler backups, and survive a holder failure.

    PYTHONPATH=src python examples/serve_routed.py
"""

import numpy as np

from repro.serving.engine import EngineConfig, Request, ServingEngine


def main():
    rng = np.random.RandomState(0)
    eng = ServingEngine(n_instances=8, pool_tokens=1_000_000,
                        instances_per_pod=4)

    # canonical corpus: 12 chunks spread across instances
    chunks = []
    for i in range(12):
        cid = f"annual_report_{2014 + i}"
        eng.register_chunk(cid, holder=i % 8, length=2048)
        chunks.append(cid)

    print("=== steady-state decode: tenants fan out over the corpus ===")
    for step in range(3):
        reqs = [Request(req_id=t, home=rng.randint(8),
                        chunk_ids=list(rng.choice(chunks, 2, replace=False)),
                        m_q=16)
                for t in range(12)]
        recs = eng.schedule_step(reqs)
        by_kind = {}
        for r in recs:
            by_kind.setdefault(r.primitive, []).append(r)
        summary = {k: len(v) for k, v in by_kind.items()}
        print(f"step {step}: dispatches {summary}, "
              f"critical path {eng.step_latency(eng.step_idx)*1e6:.0f}us")

    print("\n=== hot chunk: 20 tenants hammer one document (§6.3) ===")
    hot = chunks[0]
    reqs = [Request(req_id=100 + t, home=(t % 7) + 1, chunk_ids=[hot], m_q=8)
            for t in range(20)]
    recs = eng.schedule_step(reqs)
    for r in recs:
        print(f"  {r.primitive:>14} holder={r.holder} n_req={r.n_requesters}"
              f" m_q={r.m_q_total} est={r.est_cost_s*1e6:.0f}us")
    print(f"  holders of {hot} now: {eng.store.holders_of(hot)} "
          f"(replica spawned past the fan-in cap of "
          f"{eng.cfg.fanin_cap})")

    print("\n=== straggler: instance 2 runs 5x slow ===")
    eng.set_straggler(2, 5.0)
    victim = [c for c in chunks if eng.store.lookup(c).holder == 2][0]
    eng.store.add_replica(victim, 5)
    recs = eng.schedule_step([Request(200, home=0, chunk_ids=[victim],
                                      m_q=16)])
    for r in recs:
        tag = " (backup)" if r.backup else ""
        print(f"  {r.primitive:>14} holder={r.holder} "
              f"est={r.est_cost_s*1e6:.0f}us{tag}")
    print(f"  critical path {eng.step_latency(eng.step_idx)*1e6:.0f}us "
          f"(backup capped the straggler)")

    print("\n=== holder failure: instance 3 dies ===")
    orphaned = eng.fail_instance(3)
    print(f"  orphaned chunks (re-prefill via LOCAL): {orphaned}")
    live = [i.idx for i in eng.instances if i.alive]
    reqs = [Request(300 + t, home=int(rng.choice(live)),
                    chunk_ids=list(rng.choice(chunks, 2, replace=False)))
            for t in range(6)]
    recs = eng.schedule_step(reqs)
    assert all(r.holder != 3 for r in recs)
    print(f"  step after failure: {len(recs)} dispatches, none to the dead "
          f"instance; primitives used: {sorted({r.primitive for r in recs})}")


if __name__ == "__main__":
    main()
